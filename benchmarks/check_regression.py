"""Perf-trajectory regression gate over the committed BENCH baselines.

Compares a freshly produced bench JSON (``benchmarks/serving_bench.py``
or ``benchmarks/policy_bench.py``) against the canonical committed
baseline (``BENCH_serving.json`` / ``BENCH_policy.json``) with
per-key tolerance classes:

- **EXACT** — workload-shape keys (request counts, concurrency levels,
  mixes, config names) and correctness booleans (``tokens_match``,
  ``deterministic_rerun``).  Any drift is a failure: either the bench
  definition changed (update the baseline deliberately) or a
  correctness invariant broke.
- **TIGHT** — deterministic-per-workload counters (tokens generated
  under greedy decoding, prefix-hit/pages-shared accounting, budget
  errors).  Small relative tolerance absorbs scheduling jitter in
  arrival-timed sections while still catching real accounting bugs.
- **PERF** — wall-clock-derived numbers (tok/s, latency percentiles,
  sampler seconds, arrival-dependent queue counters).  Wide band:
  CI machines are noisy; the trajectory matters, not the third digit.

Two gate levels:

- ``--level invariants`` (the blocking CI step) checks EXACT + TIGHT
  and ignores PERF drift — a machine being slow never blocks a merge,
  a correctness or accounting regression always does.
- ``--level all`` (the advisory CI step) also enforces the PERF band,
  surfacing genuine slowdowns as a non-blocking signal first.

Asymmetry by design: a key *missing* from the fresh results is a
failure (the bench shrank or broke), but a fresh-only key — a section
the baseline predates, e.g. a newly added bench — only WARNS at every
level.  Growing the bench never blocks the PR that grows it; the new
keys start gating once the refreshed baseline is committed.

Exit code 0 = within tolerance, 1 = regression, 2 = usage/IO error.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --kind serving --baseline BENCH_serving.json \
        --fresh BENCH_serving.fresh.json --level invariants
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Iterator, Tuple

EXACT, TIGHT, PERF = "EXACT", "TIGHT", "PERF"

# Leaf-key classification (matched on the final path component).
# Workload shape + correctness booleans: must not drift at all.
_EXACT_KEYS = {
    "smoke", "levels", "concurrency", "requests", "users", "max_new",
    "sys_prompt_len", "tail_len", "prefill_chunk", "mix", "name",
    "hashed", "config", "tokens_match", "deterministic_rerun",
    "budget", "budget_target", "n_slots", "page_size",
    "spec_k", "draft_policy",
    # sharded serving: mesh geometry is workload shape — a baseline
    # produced on an 8-device host mesh must be gated on one
    "devices", "tp",
    # http_traffic: arrival-process shape + SLO definitions.  The
    # attainment/goodput numbers those SLOs produce are PERF; the
    # process generating the load must not drift silently.
    "arrival", "rate_rps", "slo_ttft_s", "slo_e2e_s",
    "bursts", "burst_size", "quota_pages", "models",
}
# Deterministic-per-workload accounting: tight relative band.
_TIGHT_KEYS = {
    "tokens", "done", "prefix_hit_rate", "pages_saved_frac",
    "pages_shared", "pages_fresh", "hit_tokens", "miss_tokens",
    "indexed_pages", "evictions", "budget_error", "worst_budget_error",
    "bank_real_params", "bank_total_params", "model_real_params",
    "prefix.hit_tokens", "prefix.miss_tokens", "prefix.indexed_pages",
    "prefix.evictions", "kv.pages_shared", "kv.pages_fresh",
    "engine.tokens", "engine.done", "kv.leak_anomalies",
    "accept_rate", "mean_accept_len", "draft_dispatches",
    "verify_dispatches",
    # batched ragged prefill: fused-dispatch accounting is a pure
    # function of the workload shape + prefill budget (deterministic
    # grouping), so it gates tightly in both the row keys and the raw
    # registry-delta names
    "prefill_batch_dispatches", "prefill_batch_rows",
    "prefill_batch_tokens", "fallback_chunks",
    "engine.prefill_batch.dispatches", "engine.prefill_batch.rows",
    "engine.prefill_batch.tokens",
    "engine.prefill_batch.fallback_chunks",
    # sharded serving: dispatch counts are a pure function of the
    # (deterministic, burst-arrival) workload shape
    "shard_decode_dispatches", "shard_prefill_dispatches",
    "engine.shard.decode_dispatches", "engine.shard.prefill_dispatches",
    # http_traffic: greedy decoding + fixed max_tokens + a queue deep
    # enough to never refuse make these exact per-workload counters
    "completed", "rejected_429", "expired_504",
}
# Sections whose token streams are sampled / arrival-order dependent:
# even "tokens" class keys degrade to PERF there (stop sequences fire
# on sampled tokens; level benches admit on wall-clock arrivals).
_PERF_SECTIONS = ("mixed_sampling", "levels", "obs_overhead")


def classify(path: Tuple[str, ...]) -> str:
    leaf = path[-1]
    # http_traffic per-model token totals: leaves are model names, so
    # the parent key — not the leaf — carries the class
    if len(path) >= 2 and path[-2] == "per_model_tokens":
        return TIGHT
    if leaf in _EXACT_KEYS:
        return EXACT
    if leaf in _TIGHT_KEYS:
        if any(s in path for s in _PERF_SECTIONS):
            return PERF
        return TIGHT
    return PERF


def walk(node, path=()) -> Iterator[Tuple[Tuple[str, ...], object]]:
    if isinstance(node, dict):
        for k, v in node.items():
            yield from walk(v, path + (str(k),))
    elif isinstance(node, list) and any(isinstance(v, (dict, list))
                                        for v in node):
        # lists of rows recurse (index as path component); flat scalar
        # lists (bucket edges, mixes) stay whole-value leaves
        for i, v in enumerate(node):
            yield from walk(v, path + (str(i),))
    else:
        yield path, node


def _close(a, b, rel: float, abs_slack: float) -> bool:
    if isinstance(a, bool) or isinstance(b, bool) \
            or not isinstance(a, (int, float)) \
            or not isinstance(b, (int, float)):
        return a == b
    return abs(a - b) <= abs_slack + rel * max(abs(a), abs(b))


def compare(baseline: dict, fresh: dict, *, level: str,
            tight_tol: float, perf_tol: float, perf_abs: float = 0.25):
    """Yields (severity, message) problems; severity 'fail'|'warn'."""
    fresh_map = dict(walk(fresh))
    base_map = dict(walk(baseline))
    for path, bval in base_map.items():
        key = ".".join(path)
        cls = classify(path)
        if path not in fresh_map:
            yield "fail", f"missing key in fresh results: {key}"
            continue
        fval = fresh_map.pop(path)
        if cls == EXACT:
            if fval != bval:
                yield "fail", (f"[EXACT] {key}: baseline {bval!r} "
                               f"!= fresh {fval!r}")
        elif cls == TIGHT:
            if not _close(fval, bval, tight_tol, 1.0):
                yield "fail", (f"[TIGHT] {key}: baseline {bval!r} vs "
                               f"fresh {fval!r} (tol {tight_tol:.0%})")
        elif level == "all":
            # relative band + absolute slack: near-zero PERF values
            # (overhead fractions, sub-second latencies) would otherwise
            # flap on any noise
            if not _close(fval, bval, perf_tol, perf_abs):
                yield "fail", (f"[PERF] {key}: baseline {bval!r} vs "
                               f"fresh {fval!r} (tol {perf_tol:.0%})")
    for path in fresh_map:
        yield "warn", f"new key not in baseline: {'.'.join(path)}"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", choices=("serving", "policy"),
                    required=True, help="which bench family (sets the "
                    "default baseline path)")
    ap.add_argument("--baseline", default=None,
                    help="committed canonical JSON "
                         "(default BENCH_<kind>.json)")
    ap.add_argument("--fresh", required=True,
                    help="freshly produced bench JSON to gate")
    ap.add_argument("--level", choices=("invariants", "all"),
                    default="invariants",
                    help="invariants: EXACT+TIGHT only (blocking CI "
                         "gate); all: also enforce the PERF band "
                         "(advisory CI gate)")
    ap.add_argument("--tight-tol", type=float, default=0.05,
                    help="relative tolerance for TIGHT keys")
    ap.add_argument("--perf-tol", type=float, default=0.75,
                    help="relative tolerance for PERF keys "
                         "(--level all)")
    ap.add_argument("--perf-abs", type=float, default=0.25,
                    help="absolute slack for PERF keys (--level all)")
    args = ap.parse_args()
    baseline_path = args.baseline or f"BENCH_{args.kind}.json"
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
        with open(args.fresh) as f:
            fresh = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"check_regression: cannot load inputs: {e}",
              file=sys.stderr)
        return 2

    fails = warns = 0
    for sev, msg in compare(baseline, fresh, level=args.level,
                            tight_tol=args.tight_tol,
                            perf_tol=args.perf_tol,
                            perf_abs=args.perf_abs):
        if sev == "fail":
            fails += 1
            print(f"FAIL  {msg}")
        else:
            warns += 1
            print(f"warn  {msg}")
    n = len(dict(walk(baseline)))
    print(f"check_regression[{args.kind}/{args.level}]: {n} baseline "
          f"keys, {fails} failures, {warns} warnings "
          f"({'REGRESSION' if fails else 'ok'})")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
