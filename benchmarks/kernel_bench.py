"""Hashed decompress-GEMM benchmark: execution paths x shapes x
compression.

On this CPU container wall-times are a *proxy* (Pallas runs in interpret
mode; XLA CPU executes the scan/materialize paths natively).  The
TPU-meaningful numbers reported per case are structural:

- VMEM working set implied by the kernel BlockSpecs (must be < ~16 MB),
- HBM bytes moved per call with compressed vs dense weights (the paper's
  deliverable at serving time),
- arithmetic intensity (flops / HBM byte) — shows which shapes flip from
  memory- to compute-bound once weights are hashed.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HashedSpec, hashed, init
from repro.kernels import ops, ref

CASES = [
    # (m, rows, cols, compression, mode)
    (256, 1024, 1024, 0.125, "element"),
    (256, 1024, 1024, 1 / 64, "element"),
    (256, 4096, 4096, 0.125, "element"),
    (256, 1024, 1024, 0.125, "block"),
    (256, 4096, 4096, 0.125, "block"),
    (16, 4096, 4096, 0.125, "block"),       # decode-like skinny batch
]


def _time(fn, *args, reps=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(quick: bool = False) -> List[Dict]:
    rows = []
    cases = CASES[:3] if quick else CASES
    for m, r, c, comp, mode in cases:
        spec = HashedSpec((r, c), comp, mode=mode, seed=3,
                          panel_cols=(512 if mode == "element" else 0),
                          block_shape=(128, 128))
        w = init(jax.random.PRNGKey(0), spec, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (m, r), jnp.float32)

        flops = 2.0 * m * r * c
        dense_bytes = (m * r + r * c + m * c) * 4
        hashed_bytes = (m * r + spec.real_param_count() + m * c) * 4

        scan = jax.jit(lambda x, w: hashed.matmul(x, w, spec, path="scan"))
        mat = jax.jit(lambda x, w: hashed.matmul(
            x, w, spec, path="materialize"))
        t_scan = _time(scan, x, w)
        t_mat = _time(mat, x, w)
        # correctness cross-check on the fly
        np.testing.assert_allclose(np.asarray(scan(x, w)),
                                   np.asarray(mat(x, w)), rtol=2e-4,
                                   atol=2e-4)
        row = {
            "case": f"{mode} {m}x{r}x{c} c=1/{round(1/comp)}",
            "us_scan": round(t_scan * 1e6, 1),
            "us_materialize": round(t_mat * 1e6, 1),
            "gflops_cpu_scan": round(flops / t_scan / 1e9, 2),
            "dense_MB": round(dense_bytes / 1e6, 2),
            "hashed_MB": round(hashed_bytes / 1e6, 2),
            "traffic_reduction": round(dense_bytes / hashed_bytes, 2),
            "intensity_dense": round(flops / dense_bytes, 1),
            "intensity_hashed": round(flops / hashed_bytes, 1),
        }
        if mode == "block":
            bm = 128
            kp_bytes = 0
            vmem = (bm * 128 + 128 * 128 + bm * 128) * 4 + kp_bytes
            row["kernel_vmem_KB"] = round(vmem / 1024, 1)
        else:
            kp = spec.buckets_per_panel
            vmem = (128 * 128 * 3) * 4 + kp * 4
            row["kernel_vmem_KB"] = round(vmem / 1024, 1)
        rows.append(row)
        print(f"  {row['case']:34s} scan {row['us_scan']:>9.1f}us  "
              f"mat {row['us_materialize']:>9.1f}us  "
              f"traffic x{row['traffic_reduction']:.1f} "
              f"AI {row['intensity_dense']:.0f}->"
              f"{row['intensity_hashed']:.0f} "
              f"VMEM {row['kernel_vmem_KB']}KB", flush=True)
    return rows


def main(quick=False, out_json=None):
    print("== hashed decompress-GEMM paths ==")
    rows = run(quick)
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
