"""Artifact benchmark: on-disk size + cold-start latency vs dense baseline.

Measures, for a dense config and its hashed variant (fp32 / int8 / fp8
exports):

- on-disk bytes, against the theoretical floor
  ``compression x dense_bytes`` for the hashed banks (acceptance: fp32
  hashed artifact within 10% of theory — header + alignment + uncompressed
  norm/embed leaves are the only slack),
- cold-start load latency: artifact mmap -> params on device, vs the
  per-leaf .npy checkpoint restore path,
- first-token latency (prefill compile excluded and included) so the
  serving story is end to end.

    PYTHONPATH=src python -m benchmarks.artifact_bench [--quick]

A mid-sized config (d_model 256, 4 layers, ~8M virtual params) keeps the
header overhead <1% so the size comparison is meaningful, while still
running in seconds on CPU.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro import artifact
from repro.artifact import format as afmt
from repro.artifact import report as areport
from repro.configs.reduced import reduced
from repro.models import build
from repro.train import checkpoint as ckpt_lib


def bench_cfg(quick: bool):
    base = reduced(C.get("qwen3-1.7b")).with_(dtype="float32")
    if not quick:
        base = base.with_(d_model=256, num_heads=8, num_kv_heads=4,
                          head_dim=32, d_ff=1024, num_layers=4,
                          vocab_size=4096, name="qwen3-bench")
    return base


def _dense_bytes(header) -> int:
    """What a dense fp32 checkpoint of the same virtual model stores."""
    rows = areport.artifact_rows(header)
    return areport.totals(rows)["virtual_bytes"]


def _theory_bytes(header) -> int:
    """compression x dense for banks; stored size for everything else."""
    total = 0
    for e in header["leaves"]:
        n = int(np.prod(e["shape"])) if e["shape"] else 1
        total += n * areport._dtype_size(e["dtype"])
    return total


def time_cold_start(path: str, reps: int = 3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _, model, params = artifact.load_model(path)
        jax.block_until_ready(jax.tree.leaves(params))
        best = min(best, time.perf_counter() - t0)
    return best, model, params


def time_ckpt_restore(ck_dir: str, target, reps: int = 3):
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        state = ckpt_lib.restore(ck_dir, target)
        jax.block_until_ready(jax.tree.leaves(state))
        best = min(best, time.perf_counter() - t0)
    return best


def dir_bytes(d: str) -> int:
    return sum(os.path.getsize(os.path.join(r, f))
               for r, _, fs in os.walk(d) for f in fs)


def main(quick: bool = False, out_json: str = None) -> dict:
    results = {}
    work = tempfile.mkdtemp(prefix="artifact_bench_")
    try:
        for tag, cfg in [("dense", bench_cfg(quick)),
                         ("hashed8", bench_cfg(quick).hashed_variant(1 / 8))]:
            m = build(cfg)
            params = m.init(jax.random.PRNGKey(0))
            n_virtual = None

            # baseline: generic per-leaf .npy checkpoint (params only)
            ck = os.path.join(work, f"ck_{tag}")
            ckpt_lib.save({"params": params}, ck, 0, keep=1)
            ck_path = os.path.join(ck, "step_00000000")
            ck_size = dir_bytes(ck_path)
            t_ck = time_ckpt_restore(
                ck, jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                    {"params": params}))

            row = {"config": cfg.name, "ckpt_bytes": ck_size,
                   "ckpt_restore_s": round(t_ck, 4), "exports": {}}
            for scheme in (("none", "int8", "fp8") if tag == "hashed8"
                           else ("none",)):
                path = os.path.join(work, f"{tag}_{scheme}.hnart")
                header = artifact.export_model(path, cfg, params,
                                               quant=scheme)
                size = os.path.getsize(path)
                if n_virtual is None:
                    n_virtual = _dense_bytes(header)
                theory = _theory_bytes(header)
                t_cold, model2, params2 = time_cold_start(path)
                # first-token: prefill compile + run from cold params
                tok = jnp.asarray([[3, 5, 7, 9]])
                t0 = time.perf_counter()
                logits, _ = jax.jit(model2.prefill)(
                    params2, {"tokens": tok,
                              "cache": model2.init_cache(1, 64)})
                jax.block_until_ready(logits)
                t_first = time.perf_counter() - t0
                row["exports"][scheme] = {
                    "bytes": size,
                    "theory_bytes": theory,
                    "size_vs_theory": round(size / max(theory, 1), 4),
                    "vs_dense_ckpt": round(size / max(ck_size, 1), 4),
                    "cold_start_s": round(t_cold, 4),
                    "first_token_s": round(t_first, 4),
                }
                if scheme == "none":
                    print(areport.report(header))
                    print()
            row["virtual_bytes"] = n_virtual
            results[tag] = row

        # headline numbers
        h = results["hashed8"]["exports"]["none"]
        d = results["dense"]["exports"]["none"]
        summary = {
            "disk_ratio_hashed_vs_dense":
                round(h["bytes"] / max(d["bytes"], 1), 4),
            "hashed_size_vs_theory": h["size_vs_theory"],
            "int8_extra":
                round(results["hashed8"]["exports"]["int8"]["bytes"]
                      / max(h["bytes"], 1), 4),
            "cold_start_vs_ckpt_restore":
                round(h["cold_start_s"]
                      / max(results["hashed8"]["ckpt_restore_s"], 1e-9), 4),
        }
        results["summary"] = summary
        print(json.dumps(results, indent=1))
        ok = abs(h["size_vs_theory"] - 1.0) <= 0.10
        print(f"\nfp32 hashed artifact vs theory: "
              f"{h['size_vs_theory']:.4f} "
              f"({'OK (within 10%)' if ok else 'EXCEEDS 10%'})")
        if out_json:
            os.makedirs(os.path.dirname(out_json) or ".", exist_ok=True)
            with open(out_json, "w") as f:
                json.dump(results, f, indent=1)
    finally:
        shutil.rmtree(work, ignore_errors=True)
    return results


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--out", default=None)
    args = p.parse_args()
    main(quick=args.quick, out_json=args.out)
