"""Benchmark orchestrator — one benchmark per paper table/figure plus the
kernel micro-bench and the dry-run roofline table.

    python -m benchmarks.run                 # default (moderate) sizes
    python -m benchmarks.run --quick         # CI profile (~5 min)
    python -m benchmarks.run --full          # paper-scale sizes (hours)
    python -m benchmarks.run --only tables   # tables|figures|kernels|roofline
"""
from __future__ import annotations

import argparse
import os
import time


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true")
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default=None,
                   choices=[None, "tables", "figures", "kernels",
                            "roofline"])
    p.add_argument("--out", default="runs/bench")
    args = p.parse_args()
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()

    def want(name):
        return args.only in (None, name)

    if want("kernels"):
        from benchmarks import kernel_bench
        print("\n################ KERNELS "
              "(hashed decompress-GEMM) ################")
        kernel_bench.main(quick=args.quick,
                          out_json=os.path.join(args.out, "kernels.json"))

    if want("tables"):
        from benchmarks import paper_tables
        print("\n################ PAPER TABLES 1 & 2 ################")
        paper_tables.main(quick=args.quick, full=args.full,
                          out_json=os.path.join(args.out, "tables.json"))

    if want("figures"):
        from benchmarks import paper_figures
        print("\n################ PAPER FIGURES 2-4 ################")
        paper_figures.main(quick=args.quick,
                           out_json=os.path.join(args.out, "figures.json"))

    if want("roofline"):
        from benchmarks import roofline_table
        print("\n################ ROOFLINE (from dry-run) ################")
        for d in ("runs/dryrun_final", "runs/dryrun"):
            rows = roofline_table.load(d)
            if rows:
                print(f"[{d}]")
                print(roofline_table.fmt(rows))
                break
        else:
            print("(no dry-run artifacts found; run repro.launch.dryrun "
                  "--all --both-meshes --out runs/dryrun_final)")

    print(f"\ntotal bench wall time: {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
