"""Paper Tables 1 & 2: test error across eight datasets, six methods,
3- and 5-layer nets, at compression 1/8 (Table 1) and 1/64 (Table 2).

Offline adaptation (DESIGN.md §6): synthetic dataset analogues, shared
hand-tuned training recipe, scaled-down sizes by default (full paper sizes
via --full).  The validation target is the paper's ORDERINGS, not its
absolute numbers; assert_paper_claims() checks them explicitly.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.data import mnist_synthetic as D
from repro.paper import mlp, train as T

METHODS = ("rer", "lrd", "nn", "dk", "hashed", "hashed_dk")
PAPER_NAME = {"rer": "RER", "lrd": "LRD", "nn": "NN", "dk": "DK",
              "hashed": "HashNet", "hashed_dk": "HashNetDK"}


def run_table(compression: float, *, datasets=None, hidden=500,
              depths=(3, 5), n_train=2500, n_test=2000, epochs=12,
              seed=0, verbose=True) -> List[Dict]:
    datasets = datasets or D.DATASETS
    cfg = T.TrainConfig(epochs=epochs, distill_temp=2.0, distill_alpha=0.7)
    rows = []
    for ds in datasets:
        x, y = D.load(ds, "train", n=n_train, seed=seed)
        xt, yt = D.load(ds, "test", n=n_test, seed=seed + 1)
        ncls = D.num_classes(ds)
        for depth in depths:
            dims = (784,) + (hidden,) * (depth - 2) + (ncls,)
            tspec = mlp.MLPSpec(dims, method="dense", dropout=0.3,
                                input_dropout=0.1, seed=seed)
            tparams, _ = T.fit(tspec, x, y, cfg=cfg, seed=seed)
            teacher = (tspec, tparams)
            for method in METHODS:
                t0 = time.time()
                r = T.run_method(method, dims, compression, x, y, xt, yt,
                                 cfg, seed=seed, teacher=teacher)
                r.update({"dataset": ds, "depth": depth,
                          "wall_s": round(time.time() - t0, 1)})
                rows.append(r)
                if verbose:
                    print(f"  {ds:11s} {depth}L {PAPER_NAME[method]:10s} "
                          f"err {r['test_err']*100:6.2f}%  "
                          f"({r['wall_s']}s)", flush=True)
    return rows


def format_table(rows: List[Dict]) -> str:
    datasets = sorted({r["dataset"] for r in rows},
                      key=list(D.DATASETS).index)
    depths = sorted({r["depth"] for r in rows})
    out = []
    for depth in depths:
        out.append(f"--- {depth}-layer ---")
        hdr = f"{'dataset':12s}" + "".join(
            f"{PAPER_NAME[m]:>11s}" for m in METHODS)
        out.append(hdr)
        for ds in datasets:
            cells = []
            vals = {r["method"]: r["test_err"] for r in rows
                    if r["dataset"] == ds and r["depth"] == depth}
            best = min(vals.values())
            for m in METHODS:
                v = vals[m]
                mark = "*" if abs(v - best) < 1e-9 else " "
                cells.append(f"{v*100:9.2f}{mark} ")
            out.append(f"{ds:12s}" + "".join(cells))
    return "\n".join(out)


def assert_paper_claims(rows_8: List[Dict], rows_64: List[Dict]) -> List[str]:
    """The paper's qualitative claims, checked on our data:
    C1 (Table 2): at 1/64, HashNet beats RER and LRD on (almost) every
        dataset, and beats NN on average by a wide margin.
    C2: HashNet degrades less from 1/8 -> 1/64 than NN/RER/LRD.
    C3 (Table 1): at 1/8, HashNet is competitive with the best baseline
        (within 2% absolute of NN on average)."""
    msgs = []

    def mean_err(rows, method):
        return float(np.mean([r["test_err"] for r in rows
                              if r["method"] == method]))

    h64, n64 = mean_err(rows_64, "hashed"), mean_err(rows_64, "nn")
    r64, l64 = mean_err(rows_64, "rer"), mean_err(rows_64, "lrd")
    ok1 = h64 < n64 and h64 < r64 and h64 < l64
    msgs.append(f"C1 {'PASS' if ok1 else 'FAIL'}: 1/64 mean err "
                f"HashNet {h64*100:.1f}% vs NN {n64*100:.1f}% "
                f"RER {r64*100:.1f}% LRD {l64*100:.1f}%")

    h8, n8 = mean_err(rows_8, "hashed"), mean_err(rows_8, "nn")
    r8, l8 = mean_err(rows_8, "rer"), mean_err(rows_8, "lrd")
    degr = {m: mean_err(rows_64, m) - mean_err(rows_8, m)
            for m in ("hashed", "nn", "rer", "lrd")}
    ok2 = degr["hashed"] <= min(degr["nn"], degr["rer"], degr["lrd"])
    msgs.append(f"C2 {'PASS' if ok2 else 'FAIL'}: 1/8->1/64 degradation "
                + " ".join(f"{m}:{d*100:+.1f}%" for m, d in degr.items()))

    ok3 = h8 <= n8 + 0.02
    msgs.append(f"C3 {'PASS' if ok3 else 'FAIL'}: 1/8 mean err "
                f"HashNet {h8*100:.1f}% vs NN {n8*100:.1f}%")
    return msgs


def main(quick=False, full=False, out_json=None):
    kw = {}
    if quick:
        kw = dict(datasets=("basic", "rot", "rect"), hidden=300,
                  n_train=1500, n_test=1000, epochs=8)
    if full:
        kw = dict(hidden=1000, n_train=12000, n_test=10000, epochs=30)
    print("== Table 1 (compression 1/8) ==", flush=True)
    rows_8 = run_table(1 / 8, **kw)
    print(format_table(rows_8))
    print("\n== Table 2 (compression 1/64) ==", flush=True)
    rows_64 = run_table(1 / 64, **kw)
    print(format_table(rows_64))
    print()
    msgs = assert_paper_claims(rows_8, rows_64)
    for m in msgs:
        print(m)
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"table1": rows_8, "table2": rows_64,
                       "claims": msgs}, f, indent=1)
    return rows_8, rows_64, msgs


if __name__ == "__main__":
    main()
