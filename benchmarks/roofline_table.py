"""Render the roofline table from runs/dryrun/*.json (dry-run outputs).

    python -m benchmarks.roofline_table [--dir runs/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(directory: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt(rows: List[Dict], md: bool = False) -> str:
    cols = ["arch", "shape", "mesh", "hashed", "compute_s", "memory_s",
            "collective_s", "dominant", "useful", "roofline"]
    out = []
    sep = " | " if md else "  "
    hdr = sep.join([f"{c:>12s}" if i > 3 else f"{c:<22s}" if i == 0
                    else f"{c:<12s}" for i, c in enumerate(cols)])
    out.append(hdr)
    if md:
        out.append(sep.join(["---"] * len(cols)))
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r.get("multi_pod", False))):
        mesh = "2x16x16" if r.get("multi_pod") else "16x16"
        vals = [
            f"{r['arch']:<22s}", f"{r['shape']:<12s}", f"{mesh:<12s}",
            f"{str(r.get('hashed', False)):<6s}",
            f"{r['compute_s']*1e3:11.1f}ms", f"{r['memory_s']*1e3:11.1f}ms",
            f"{r['collective_s']*1e3:11.1f}ms",
            f"{r['dominant']:>12s}",
            f"{r['useful_flops_ratio']:12.2f}",
            f"{r['roofline_fraction']:12.3f}",
        ]
        out.append(sep.join(vals))
    return "\n".join(out)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="runs/dryrun")
    p.add_argument("--md", action="store_true")
    args = p.parse_args()
    rows = load(args.dir)
    if not rows:
        print(f"no dry-run JSON in {args.dir} — run "
              "`python -m repro.launch.dryrun --all --both-meshes "
              f"--out {args.dir}` first")
        return 1
    print(fmt(rows, args.md))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
